"""Quickstart: the paper's pipeline in 30 lines — on the Spinner API.

Estimate three kernels with a circulant 1-block SpinnerPipeline using n
Gaussians instead of m*n, show the budget knob (circulant -> toeplitz ->
unstructured), then stack blocks (TripleSpin-style) — same protocol,
same estimator, three fused dispatches.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core import spinner


def main():
    n, m = 128, 512
    v1 = jax.random.normal(jax.random.PRNGKey(1), (n,))
    v1 = v1 / jnp.linalg.norm(v1)
    v2 = 0.6 * v1 + 0.8 * jax.random.normal(jax.random.PRNGKey(2), (n,))
    v2 = v2 / jnp.linalg.norm(v2)

    print(f"input dim n={n}, embedding dim m={m}")
    for kind in ["circulant", "toeplitz", "unstructured"]:
        pipe = spinner.single(kind, m=m, n=n)
        params = pipe.init(jax.random.PRNGKey(0))
        print(f"\n[{kind}] budget of randomness t={pipe.budget} "
              f"(dense would use {m*n}); storage={pipe.storage} floats")
        for fname in ["heaviside", "relu", "trig", "softmax"]:
            est = float(E.estimate(pipe, params, fname, v1, v2))
            ex = float(E.exact(fname, v1, v2))
            print(f"  {fname:10s} estimate={est:+.4f}  exact={ex:+.4f}  "
                  f"|err|={abs(est-ex):.4f}")

    # stacked spinners: HD3.HD2.HD1 (depth 3) — the same estimator runs
    # through a chain of fused blocks; storage stays O(n) per block.
    pipe3 = spinner.hd_chain("circulant", n=n, m=m, depth=3)
    params3 = pipe3.init(jax.random.PRNGKey(0))
    print(f"\n[circulant x3 stacked] t={pipe3.budget}, "
          f"storage={pipe3.storage} floats, depth={pipe3.depth}")
    for fname in ["heaviside", "trig"]:
        est = float(E.estimate(pipe3, params3, fname, v1, v2))
        print(f"  {fname:10s} estimate={est:+.4f}")


if __name__ == "__main__":
    main()
