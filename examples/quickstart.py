"""Quickstart: the paper's pipeline in 30 lines.

Estimate three kernels with a circulant P-model using n Gaussians instead
of m*n, then show the budget knob (circulant -> toeplitz -> unstructured).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core import pmodel as P
from repro.core import structured as S


def main():
    n, m = 128, 512
    v1 = jax.random.normal(jax.random.PRNGKey(1), (n,))
    v1 = v1 / jnp.linalg.norm(v1)
    v2 = 0.6 * v1 + 0.8 * jax.random.normal(jax.random.PRNGKey(2), (n,)) / jnp.sqrt(n) * jnp.sqrt(n)
    v2 = v2 / jnp.linalg.norm(v2)

    print(f"input dim n={n}, embedding dim m={m}")
    for kind in ["circulant", "toeplitz", "unstructured"]:
        spec = P.PModelSpec(kind=kind, m=m, n=n, use_hd=True)
        params = P.init(jax.random.PRNGKey(0), spec)
        print(f"\n[{kind}] budget of randomness t={spec.budget} "
              f"(dense would use {m*n}); storage={spec.storage} floats")
        for fname in ["heaviside", "relu", "trig", "softmax"]:
            est = float(E.estimate(spec, params, fname, v1, v2))
            ex = float(E.exact(fname, v1, v2))
            print(f"  {fname:10s} estimate={est:+.4f}  exact={ex:+.4f}  "
                  f"|err|={abs(est-ex):.4f}")


if __name__ == "__main__":
    main()
