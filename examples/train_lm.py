"""End-to-end driver: train an LM with the full production stack —
sharded synthetic data, AdamW + cosine schedule, atomic checkpoints with
auto-resume, straggler watchdog — on a reduced config sized for CPU.

The same Trainer drives full-size configs on a real mesh; pass
--arch/--steps to taste. With --attn srf the model trains with the
paper's structured random-feature attention end to end.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--attn srf]
"""
import argparse

from repro.configs import registry
from repro.launch.steps import TrainHyper
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--attn", default="full", choices=["full", "srf"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = registry.reduced(args.arch, attn_impl=args.attn, n_layers=2)
    tcfg = TrainerConfig(
        num_steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=40, log_every=10,
        hyper=TrainHyper(lr=1e-2, warmup=20, total_steps=args.steps))
    tr = Trainer(cfg, tcfg)
    resumed = tr.try_resume()
    print(f"arch={args.arch} attn={args.attn} "
          f"params={sum(x.size for x in __import__('jax').tree.leaves(tr.params)):,} "
          f"resumed={resumed}")
    out = tr.train()
    first, last = out["log"][0], out["log"][-1]
    print(f"step {first['step']}: loss={first['loss']:.3f}  ->  "
          f"step {last['step']}: loss={last['loss']:.3f}")
    assert last["loss"] < first["loss"], "loss should decrease"
    print("checkpoints:", tr.ckpt.available_steps())


if __name__ == "__main__":
    main()
